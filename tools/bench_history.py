"""Perf-history CLI over ``repro.obs.history`` (``BENCH_HISTORY.json``).

The history file is the repo's perf trajectory: ``benchmarks/run.py --smoke
--history`` appends one row per emitted bench metric, and this tool answers
"did anything drift?" — cycle-level metrics are deterministic functions of
the code, so any deviation from the trailing median is a behaviour change
(improvements are flagged too; re-baseline by letting the new value
accumulate history, or prune the file).  Wall-clock metrics (``wall_ms`` /
``seconds`` / ``wall_speedup``) are never gated — host timing is noise.

    PYTHONPATH=src python tools/bench_history.py check-regression
    PYTHONPATH=src python tools/bench_history.py check-regression \
        --file BENCH_HISTORY.json --window 8 --tolerance 0.15
    PYTHONPATH=src python tools/bench_history.py show [--metric substr]
    PYTHONPATH=src python tools/bench_history.py append name=value [...]

``check-regression`` exits non-zero when any (bench, scenario, metric)
group's newest row deviates more than ``--tolerance`` (relative) from the
median of up to ``--window`` prior rows; single-row groups pass vacuously.
"""

from __future__ import annotations

import argparse
import sys

from repro.obs import history


def cmd_check(args) -> int:
    rows = history.load_history(args.file)
    if not rows:
        print(f"{args.file}: no history yet — nothing to check")
        return 0
    problems = history.check_regression(rows, window=args.window,
                                        tolerance=args.tolerance)
    if problems:
        print(f"{len(problems)} regression(s) vs trailing median:")
        for p in problems:
            print(f"  {p}")
        return 1
    groups = {(r.get("bench"), r.get("scenario"), r.get("metric")) for r in rows}
    print(f"ok: {len(rows)} rows, {len(groups)} metric groups, "
          f"newest within {args.tolerance:.0%} of trailing median "
          f"(window {args.window})")
    return 0


def cmd_show(args) -> int:
    rows = history.load_history(args.file)
    for r in rows:
        label = ".".join(p for p in (r.get("bench", ""), r.get("scenario", ""),
                                     r.get("metric", "")) if p)
        if args.metric and args.metric not in label:
            continue
        print(f"{label}\t{r.get('value')}\t{r.get('commit', '?')}\t"
              f"{r.get('date', '?')}")
    return 0


def cmd_append(args) -> int:
    rows = []
    for pair in args.rows:
        name, _, value = pair.partition("=")
        if not _:
            print(f"expected name=value, got {pair!r}", file=sys.stderr)
            return 2
        rows.append((name, float(value)))
    n = history.append_rows(args.file, rows)
    print(f"appended {n} rows to {args.file}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--file", default="BENCH_HISTORY.json",
                    help="history file (default: BENCH_HISTORY.json)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    chk = sub.add_parser("check-regression",
                         help="newest row vs trailing median per metric group")
    chk.add_argument("--window", type=int, default=8,
                     help="prior rows in the median (default 8)")
    chk.add_argument("--tolerance", type=float, default=0.15,
                     help="relative deviation band (default 0.15)")
    chk.set_defaults(fn=cmd_check)

    show = sub.add_parser("show", help="dump rows as TSV")
    show.add_argument("--metric", default=None,
                      help="only rows whose label contains this substring")
    show.set_defaults(fn=cmd_show)

    app = sub.add_parser("append", help="append name=value rows by hand")
    app.add_argument("rows", nargs="+", metavar="name=value")
    app.set_defaults(fn=cmd_append)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
