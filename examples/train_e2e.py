"""End-to-end driver: train a ~1M-param smollm-family model for a few hundred
steps on byte-level text, checkpoint, restore, and generate.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]
"""

import argparse
import tempfile

from repro import configs
from repro.checkpoint import manager
from repro.data import pipeline
from repro.launch import train as train_mod
from repro.models import registry
from repro.serving.engine import Engine, SamplerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
    out = train_mod.run("smollm-135m", smoke=True, steps=args.steps, batch=16,
                        seq=64, ckpt_dir=ckpt, ckpt_every=100, lr=3e-3)
    print(f"[e2e] loss {out['first_loss']:.3f} → {out['final_loss']:.3f}")
    assert out["final_loss"] < out["first_loss"] - 1.0, "training must learn"

    # restart-from-checkpoint proves the fault-tolerance path
    step, tree = manager.restore(ckpt)
    print(f"[e2e] restored checkpoint at step {step}")

    cfg = configs.get_config("smollm-135m", smoke=True)
    api = registry.build(cfg)
    eng = Engine(api, out["params"], batch=2, max_seq=128)
    corpus = pipeline.ByteCorpus(vocab=cfg.vocab)
    prompts = corpus.batch(seed=9, step=0, batch=2, seq=31)[:, :32]
    toks = eng.generate(prompts, n_tokens=48, sampler=SamplerConfig(temperature=0.0))
    txt = bytes(int(t) % 256 for t in toks[0]).decode(errors="replace")
    print(f"[e2e] greedy continuation: {txt!r}")


if __name__ == "__main__":
    main()
