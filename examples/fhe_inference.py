"""Encrypted neural-network inference (the paper's LoLa-style deep workload).

A 2-layer MLP with square activations (the standard FHE-friendly choice) is
evaluated homomorphically over CKKS: inputs encrypted, weights in cleartext
(LoLa-MNIST "Unencrypted Weights" variant).  The encrypted prediction is
validated against the cleartext forward pass, and the captured instruction
trace is replayed through the cycle simulator to estimate accelerator latency.

    PYTHONPATH=src python examples/fhe_inference.py
"""

import numpy as np

from repro.core import hardware as H
from repro.core.simulator import lanes_shallow, simulate_stream
from repro.fhe import FheContext, keys as K, linear, params as P, trace


def main():
    p = P.make_params(1 << 9, 6, 3, check_security=False)
    rng = np.random.default_rng(1)
    d_in, d_hidden, d_out = 16, 16, 4

    w1 = rng.normal(size=(d_in, d_hidden)) * 0.4
    w2 = rng.normal(size=(d_hidden, d_out)) * 0.4
    x = rng.normal(size=d_in) * 0.5

    # cleartext reference
    h = (x @ w1) ** 2
    want = h @ w2

    # pack x into the first d_in slots; matvec via BSGS diagonals of the
    # (slots × slots) block matrix that implements W^T on the packed layout
    def block_matrix(w):
        m = np.zeros((p.slots, p.slots))
        m[: w.shape[1], : w.shape[0]] = w.T
        return m

    plan1 = linear.plan_matrix(block_matrix(w1), tol=1e-12)
    plan2 = linear.plan_matrix(block_matrix(w2), tol=1e-12)
    rots = sorted(plan1.rotations() | plan2.rotations())
    ctx = FheContext(params=p, keys=K.full_keyset(p, seed=0, rotations=tuple(rots)))

    xin = np.zeros(p.slots)
    xin[:d_in] = x
    ct = ctx.encrypt(ctx.encode(xin))

    with trace.capture_trace() as t:
        ct = ctx.apply_bsgs(ct, plan1)  # x @ w1
        ct = ctx.square(ct)  # (·)²
        ct = ctx.apply_bsgs(ct, plan2)  # @ w2
    got = ctx.decrypt_decode(ct).real[:d_out]
    print(f"[fhe-inference] encrypted MLP err: {np.abs(got - want).max():.2e} "
          f"(|y| ~ {np.abs(want).max():.2f})")

    # replay the captured trace through the accelerator model
    stream = list(t)
    for chip, lanes in ((H.FLASH_FHE, lanes_shallow(H.FLASH_FHE)),):
        r = simulate_stream(stream, chip, lanes)
        print(f"[fhe-inference] {chip.name} one affiliation: "
              f"{r.time_s*1e6:.0f} µs simulated, {r.instr_count} instructions")


if __name__ == "__main__":
    main()
