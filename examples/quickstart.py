"""Quickstart: CKKS in 30 lines + the FLASH-FHE heterogeneous scheduler.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import hardware as H, jobs as J, scheduler as S
from repro.fhe import FheContext, keys as K, params as P


def main():
    # --- 1. CKKS: encrypt, compute, decrypt -------------------------------
    p = P.make_params(1 << 9, 6, 2, check_security=False)  # toy ring
    ctx = FheContext(params=p, keys=K.full_keyset(p, seed=0, rotations=(1,)))
    rng = np.random.default_rng(0)
    x = rng.normal(size=p.slots) * 0.5
    y = rng.normal(size=p.slots) * 0.5

    ct_x = ctx.encrypt(ctx.encode(x))
    ct_y = ctx.encrypt(ctx.encode(y))
    ct = ctx.mul(ctx.add(ct_x, ct_y), ct_y)  # (x+y)·y
    ct = ctx.rotate(ct, 1)
    got = ctx.decrypt_decode(ct)
    want = np.roll((x + y) * y, -1)
    print(f"[quickstart] homomorphic (x+y)·y rotated: max err "
          f"{np.abs(got - want).max():.2e}")

    # --- 2. the paper's scheduler on a mixed workload ---------------------
    jobs = [J.make_job("resnet20", job_id=0)]
    jobs += [J.make_job("lola_mnist_plain", priority=1, arrival_cycle=100 + i,
                        job_id=1 + i) for i in range(8)]
    for chip in (H.FLASH_FHE, H.CRATERLAKE):
        sched = S.schedule(jobs, chip)
        sh = [s for s in sched if s.job.kind == "shallow"]
        print(f"[quickstart] {chip.name:11s}: shallow avg turnaround "
              f"{np.mean([s.turnaround for s in sh])/1e3:10.1f} kcycles, "
              f"makespan {S.makespan(sched)/1e6:.2f} Mcycles")


if __name__ == "__main__":
    main()
