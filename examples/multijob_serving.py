"""Multi-tenant FHE serving demo: real numerics + discrete-event scheduling.

Three stages:
  1. N homomorphic multiplications (one per "customer job") through the
     shard_map executor — the numerical realisation of one-shallow-job-per-
     affiliation scheduling;
  2. the classic 8-job makespan comparison vs CraterLake through the event
     engine (the paper's up-to-8× multi-job claim);
  3. an actual serving scenario: a seeded shallow-heavy Poisson stream with a
     deep background and priority preemption, plus a closed-loop "N tenants"
     run — SLO metrics (p50/p99 latency, queueing, utilization, fairness)
     per chip;
  4. fleet serving: one saturating arrival stream sharded across 1/2/4
     FLASH-FHE chips by the cluster router (throughput scaling), and a skewed
     bursty-tenant stream comparing all four dispatch policies on p99.

    PYTHONPATH=src python examples/multijob_serving.py
"""

import numpy as np

from repro import serve
from repro.core import executor as E
from repro.core import hardware as H, jobs as J, scheduler as S
from repro.fhe import FheContext, keys as K, params as P


def numeric_affiliations():
    p = P.make_params(1 << 9, 4, 2, check_security=False)
    ctx = FheContext(params=p, keys=K.full_keyset(p, seed=0))
    rng = np.random.default_rng(0)

    n_jobs = 4
    pairs, zs = [], []
    for j in range(n_jobs):
        z1 = rng.normal(size=p.slots) * 0.4
        z2 = rng.normal(size=p.slots) * 0.4
        zs.append((z1, z2))
        pairs.append((ctx.encrypt(ctx.encode(z1), seed=j),
                      ctx.encrypt(ctx.encode(z2), seed=50 + j)))

    mesh = E.affiliation_mesh(1)  # all local devices as one affiliation group
    outs = E.parallel_shallow_mul(p, ctx.keys, pairs, mesh)
    errs = [np.abs(ctx.decrypt_decode(o) - z1 * z2).max()
            for o, (z1, z2) in zip(outs, zs)]
    print(f"[multijob] {n_jobs} jobs executed in one shard_map program; "
          f"max err {max(errs):.2e}")


def makespan_comparison():
    jobs = [J.make_job("lola_mnist_plain", job_id=i) for i in range(8)]
    ff, cl = S.schedule(jobs, H.FLASH_FHE), S.schedule(jobs, H.CRATERLAKE)
    print(f"[multijob] simulated 8-job makespan: FLASH-FHE "
          f"{S.makespan(ff)/1e3:.0f} kcycles vs CraterLake "
          f"{S.makespan(cl)/1e3:.0f} kcycles "
          f"({S.makespan(cl)/S.makespan(ff):.1f}× — paper: up to 8×)")


def open_loop_serving():
    cfg = serve.PoissonConfig(rate_per_mcycle=2.0, n_jobs=64,
                              mix=serve.traffic.MIXED_MIX,
                              priority_mix={0: 0.6, 5: 0.4}, seed=17)
    jobs = serve.poisson_jobs(cfg)
    print("[serving] open-loop mixed Poisson stream "
          f"({len(jobs)} jobs, 85% shallow / 15% deep, 40% high-priority):")
    for chip in (H.FLASH_FHE, H.CRATERLAKE):
        m = serve.summarize(serve.serve(jobs, chip))
        print(f"[serving]   {chip.name:11s}: p50 {m['latency_p50_cycles']/1e6:6.2f}M  "
              f"p99 {m['latency_p99_cycles']/1e6:6.2f}M  "
              f"queue p99 {m['queue_p99_cycles']/1e6:6.2f}M  "
              f"makespan {m['makespan_mcycles']:6.1f}M  "
              f"util {m['util_mean']:.2f}  preemptions {int(m['n_preemptions'])}")
    # hoisted-rotation kernel mode, selected through an execution policy: its
    # policy_key() keys the service-time memo, so modes never alias
    hoisted = serve.ExecPolicy(backend="fused", hoisting="always")
    m = serve.summarize(serve.serve(jobs, H.FLASH_FHE, exec_policy=hoisted))
    print(f"[serving]   flash-fhe (hoisted policy): "
          f"p99 {m['latency_p99_cycles']/1e6:6.2f}M  "
          f"makespan {m['makespan_mcycles']:6.1f}M")


def closed_loop_serving():
    src = serve.ClosedLoopSource(n_tenants=8, jobs_per_tenant=4,
                                 mix=serve.traffic.SHALLOW_MIX,
                                 think_cycles=20_000, seed=3)
    m = serve.summarize(serve.serve_source(src, H.FLASH_FHE))
    print(f"[serving] closed loop, 8 tenants × 4 jobs on flash-fhe: "
          f"{int(m['n_jobs'])} jobs, p99 {m['latency_p99_cycles']/1e3:.0f} kcycles, "
          f"tenant fairness {m['fairness_jain']:.3f}")


def fleet_serving():
    # one chip saturates under this shallow-heavy stream (~6× its capacity);
    # the cluster router turns extra chips into nearly-linear throughput
    cfg = serve.PoissonConfig(rate_per_mcycle=300.0, n_jobs=320,
                              mix=serve.traffic.SHALLOW_MIX,
                              priority_mix={0: 0.7, 5: 0.3}, seed=11)
    jobs = serve.poisson_jobs(cfg)
    print("[fleet] shallow-heavy stream (320 jobs, ~6× one chip) on growing fleets:")
    base = None
    for n in (1, 2, 4):
        m = serve.summarize(serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=n, router="jsq"))
        base = base or m["throughput_jobs_per_mcycle"]
        print(f"[fleet]   {n} chip(s): {m['throughput_jobs_per_mcycle']:6.1f} jobs/Mcycle "
              f"({m['throughput_jobs_per_mcycle']/base:.2f}×)  "
              f"p99 {m['latency_p99_cycles']/1e6:5.2f}M  "
              f"imbalance {m['chip_util_imbalance']:.3f}  "
              f"cold starts {int(m['n_cold_starts'])}")

    skew = serve.BurstyConfig(
        base=serve.PoissonConfig(rate_per_mcycle=8.0, n_jobs=64,
                                 mix=serve.traffic.MIXED_MIX,
                                 priority_mix={0: 0.7, 5: 0.3}, seed=17),
        n_bursts=6, burst_size=16, burst_mix=serve.traffic.SHALLOW_MIX)
    bjobs = serve.bursty_jobs(skew)
    print("[fleet] skewed bursty-tenant stream on 4 chips, per router policy:")
    for router in ("round_robin", "po2", "jsq", "affinity"):
        m = serve.summarize(serve.serve_cluster(bjobs, H.FLASH_FHE, n_chips=4, router=router))
        print(f"[fleet]   {router:12s}: p99 {m['latency_p99_cycles']/1e6:6.2f}M  "
              f"makespan {m['makespan_mcycles']:6.1f}M  "
              f"chip fairness {m['fairness_jain_chips']:.3f}")


def main():
    numeric_affiliations()
    makespan_comparison()
    open_loop_serving()
    closed_loop_serving()
    fleet_serving()


if __name__ == "__main__":
    main()
