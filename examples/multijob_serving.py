"""Parallel shallow FHE jobs: affiliation = device group, executed for real.

Runs N homomorphic multiplications (one per "customer job") through the
shard_map executor — the numerical realisation of the paper's one-shallow-job-
per-affiliation scheduling — and compares scheduler timelines vs CraterLake.

    PYTHONPATH=src python examples/multijob_serving.py
"""

import numpy as np

from repro.core import executor as E
from repro.core import hardware as H, jobs as J, scheduler as S
from repro.fhe import keys as K, ops, params as P


def main():
    p = P.make_params(1 << 9, 4, 2, check_security=False)
    ks = K.full_keyset(p, seed=0)
    rng = np.random.default_rng(0)

    n_jobs = 4
    pairs, zs = [], []
    for j in range(n_jobs):
        z1 = rng.normal(size=p.slots) * 0.4
        z2 = rng.normal(size=p.slots) * 0.4
        zs.append((z1, z2))
        pairs.append((ops.encrypt(p, ks.pk, ops.encode(p, z1), seed=j),
                      ops.encrypt(p, ks.pk, ops.encode(p, z2), seed=50 + j)))

    mesh = E.affiliation_mesh(1)  # all local devices as one affiliation group
    outs = E.parallel_shallow_mul(p, ks, pairs, mesh)
    errs = [np.abs(ops.decrypt_decode(p, ks.sk, o) - z1 * z2).max()
            for o, (z1, z2) in zip(outs, zs)]
    print(f"[multijob] {n_jobs} jobs executed in one shard_map program; "
          f"max err {max(errs):.2e}")

    jobs = [J.make_job("lola_mnist_plain", job_id=i) for i in range(8)]
    ff, cl = S.schedule(jobs, H.FLASH_FHE), S.schedule(jobs, H.CRATERLAKE)
    print(f"[multijob] simulated 8-job makespan: FLASH-FHE "
          f"{S.makespan(ff)/1e3:.0f} kcycles vs CraterLake "
          f"{S.makespan(cl)/1e3:.0f} kcycles "
          f"({S.makespan(cl)/S.makespan(ff):.1f}× — paper: up to 8×)")


if __name__ == "__main__":
    main()
